"""Serving benchmark: offered-load sweep over the StreamEngine.

Compares three dispatch styles for the same compiled diamond app:

- ``sequential`` — one ``CompiledApp.__call__`` per request, forced to
  host memory before the next (the bare-callable baseline the runtime
  subsystem replaces),
- ``launch_pipelined`` — async ``CompiledApp.launch`` with a depth-2
  window of in-flight handles (double buffering without batching),
- ``engine[b=N]`` — the full :class:`repro.runtime.engine.StreamEngine`
  path: bounded queue, compile cache, micro-batching, double-buffered
  retirement.

Full mode sweeps micro-batch width and writes
``experiments/bench_serving.json`` plus the repo-root
``BENCH_serving.json`` baseline; ``--smoke`` runs one small
configuration in CI and asserts that micro-batched throughput beats
one-at-a-time dispatch.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import DataflowGraph, compile_graph
from repro.core.apps import JACOBI3, LAPLACE3, _conv
from repro.runtime import MicroBatcher, StreamEngine, modeled_latency

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _diamond(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("diamond")
    x = g.input("x", (h, w))
    s1 = g.stencil(x, (3, 3), _conv(LAPLACE3), name="lap")
    s2 = g.stencil(x, (3, 3), _conv(JACOBI3), name="jac")
    g.output(g.point2(s1, s2, lambda u, v: u - v, name="merge"), "y")
    return g


def _requests(h: int, w: int, n: int) -> list[np.ndarray]:
    rng = np.random.default_rng(0)
    return [rng.normal(size=(h, w)).astype(np.float32) for _ in range(n)]


def _sequential(app, reqs) -> float:
    """One-at-a-time __call__ dispatch; returns items/sec."""
    np.asarray(app(x=reqs[0])["y"])                    # warmup
    t0 = time.perf_counter()
    for x in reqs:
        np.asarray(app(x=x)["y"])
    return len(reqs) / (time.perf_counter() - t0)


def _launch_pipelined(app, reqs, depth: int = 2) -> float:
    """Async launch() with a bounded in-flight window; items/sec."""
    app.launch(x=reqs[0]).result()                     # warmup
    inflight: list = []
    t0 = time.perf_counter()
    for x in reqs:
        if len(inflight) >= depth:
            inflight.pop(0).result()
        inflight.append(app.launch(x=x))
    for h in inflight:
        h.result()
    return len(reqs) / (time.perf_counter() - t0)


class _Req:
    def __init__(self, x):
        self.inputs = {"x": x}


def _microbatched(app, mb, reqs) -> float:
    """Direct micro-batched dispatch (no engine threads); items/sec.

    This isolates the claim the smoke asserts: stacking B requests
    into ONE vmapped launch amortizes per-call dispatch overhead that
    one-at-a-time ``__call__`` pays B times.
    """
    b = mb.max_batch
    wrapped = [_Req(x) for x in reqs]
    np.asarray(mb.launch(app, wrapped[:b], pad_to=b)["y"])   # warmup
    t0 = time.perf_counter()
    outs = [mb.launch(app, wrapped[i:i + b], pad_to=b)
            for i in range(0, len(wrapped), b)]
    for o in outs:
        np.asarray(o["y"])
    return len(reqs) / (time.perf_counter() - t0)


def _engine_round(eng, g, reqs) -> float:
    """One offered-load round through a warm engine; items/sec."""
    t0 = time.perf_counter()
    handles = [eng.submit(g, {"x": x}) for x in reqs]
    for hd in handles:
        hd.result()
    return len(reqs) / (time.perf_counter() - t0)


def run(smoke: bool = False) -> list[dict]:
    # smoke: small planes so per-launch overhead dominates — the regime
    # micro-batching amortizes (and a robust margin on noisy CI hosts).
    # Modes are measured in interleaved rounds (best-of-k per mode) so
    # machine-load swings hit every mode alike instead of whichever one
    # happened to run during a slow window.
    h, w = (16, 128) if smoke else (96, 256)
    n = 128 if smoke else 192
    rounds = 3 if smoke else 2
    backend = "xla"
    batch_widths = (32,) if smoke else (2, 4, 8, 16, 32)
    reqs = _requests(h, w, n)
    g = _diamond(h, w)
    app = compile_graph(_diamond(h, w), backend=backend)
    model = modeled_latency(app, n)

    engines = {b: StreamEngine(backend=backend, max_batch=b,
                               max_queue=max(n, 2))
               for b in batch_widths}
    for eng in engines.values():
        eng.submit(g, {"x": reqs[0]}).result()         # warmup (compiles)
    mb = MicroBatcher(max_batch=max(batch_widths))
    seq_tput = pipe_tput = mb_tput = 0.0
    eng_tput = {b: 0.0 for b in batch_widths}
    for _ in range(rounds):
        seq_tput = max(seq_tput, _sequential(app, reqs))
        mb_tput = max(mb_tput, _microbatched(app, mb, reqs))
        pipe_tput = max(pipe_tput, _launch_pipelined(app, reqs))
        for b, eng in engines.items():
            eng_tput[b] = max(eng_tput[b], _engine_round(eng, g, reqs))

    rows: list[dict] = []
    rows.append({"name": "serving_sequential", "us": 1e6 / seq_tput,
                 "throughput_rps": seq_tput, "mode": "one-at-a-time",
                 "h": h, "w": w, "n": n,
                 "modeled_speedup": model["speedup"]})
    rows.append({"name": f"serving_microbatch_b{mb.max_batch}",
                 "us": 1e6 / mb_tput, "throughput_rps": mb_tput,
                 "mode": f"direct micro-batch={mb.max_batch}",
                 "h": h, "w": w, "n": n,
                 "speedup_vs_sequential": mb_tput / seq_tput})
    rows.append({"name": "serving_launch_pipelined", "us": 1e6 / pipe_tput,
                 "throughput_rps": pipe_tput, "mode": "async-depth2",
                 "h": h, "w": w, "n": n})
    for b, eng in engines.items():
        rep = eng.report(n_items=n)
        eng.close()
        m = rep["measured"]
        tput = eng_tput[b]
        rows.append({"name": f"serving_engine_b{b}", "us": 1e6 / tput,
                     "throughput_rps": tput, "mode": f"engine batch={b}",
                     "h": h, "w": w, "n": n,
                     "latency_p50_ms": m["latency_p50_ms"],
                     "latency_p99_ms": m["latency_p99_ms"],
                     "batch_size_mean": m["batch_size_mean"],
                     "cache_hit_rate": rep["cache"]["hit_rate"],
                     "speedup_vs_sequential": tput / seq_tput})
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = run(smoke=smoke)
    for r in rows:
        print(f"{r['name']}: {r['throughput_rps']:.1f} items/s"
              + (f" ({r['speedup_vs_sequential']:.2f}x vs sequential)"
                 if "speedup_vs_sequential" in r else ""))
    payload = {"rows": rows, "smoke": smoke}
    os.makedirs(os.path.join(_ROOT, "experiments"), exist_ok=True)
    with open(os.path.join(_ROOT, "experiments", "bench_serving.json"),
              "w") as f:
        json.dump(payload, f, indent=1)
    with open(os.path.join(_ROOT, "BENCH_serving.json"), "w") as f:
        json.dump(payload, f, indent=1)
    if smoke:
        seq = next(r for r in rows if r["name"] == "serving_sequential")
        best = max(r["throughput_rps"] for r in rows
                   if r["name"].startswith(("serving_microbatch",
                                            "serving_engine")))
        assert best > seq["throughput_rps"], (
            f"micro-batched dispatch ({best:.1f} items/s) did not beat "
            f"one-at-a-time dispatch ({seq['throughput_rps']:.1f} items/s)")
        print(f"smoke ok: micro-batched {best:.1f} > sequential "
              f"{seq['throughput_rps']:.1f} items/s")


if __name__ == "__main__":
    main()
