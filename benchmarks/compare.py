"""Benchmark regression gate: fresh run vs checked-in baselines.

``python benchmarks/compare.py`` diffs the JSON a fresh benchmark run
dropped into ``experiments/`` against the checked-in repo-root
baselines (``BENCH_serving.json`` / ``BENCH_parallel.json`` /
``BENCH_tuning.json``) and fails when a measured metric regressed
past its relative tolerance — the CI step that turns a silent
throughput/latency regression into a red build with a readable delta
table instead of a number nobody ever opens.

Rows are matched by **identity**: the row ``name`` plus every
non-metric scalar in the row (``h``, ``w``, ``n``, ``app``,
``vector_factor``, ...).  That matters because CI runs ``--smoke``
with smaller shapes than the full-run baselines — a smoke
``parallel_vf2`` at 64x1024 must never be timed against the full
256x640 baseline, so unmatched rows are *reported and skipped*, not
compared.  Only rows whose identity matches exactly gate the build.

Metrics and directions: ``us`` (lower is better) and
``throughput_rps`` (higher is better).  A row fails when it is more
than ``(1 + tol)`` times worse than its baseline; ``--tol`` defaults
to 2.0 (a 3x regression fails) because shared CI hosts jitter
small-shape timings enormously — the gate exists to catch
order-of-magnitude breakage, while fine-grained tracking lives in
the checked-in baselines' git history.

Exit status: 0 clean, 1 regression, and missing files are skipped
with a warning unless ``--strict`` (so the gate guards whatever
actually ran).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: metric -> direction; every other scalar row key is identity
METRICS: dict[str, str] = {
    "us": "lower",
    "throughput_rps": "higher",
    "modeled_us": "ignore",          # model output, not a measurement
    "modeled_speedup": "ignore",
    "budget_ms": "ignore",
    "latency_p50_ms": "ignore",      # tracked, but p99-of-smoke flaps
    "latency_p99_ms": "ignore",
}

#: default (baseline, fresh) pairs the CI step checks
DEFAULT_PAIRS = [
    ("BENCH_serving.json", os.path.join("experiments",
                                        "bench_serving.json")),
    ("BENCH_parallel.json", os.path.join("experiments",
                                         "bench_parallel.json")),
    ("BENCH_tuning.json", os.path.join("experiments",
                                       "bench_tuning.json")),
]


def row_key(row: dict[str, Any]) -> tuple:
    """Hashable identity of a benchmark row: name + non-metric scalars."""
    parts = [("name", str(row.get("name")))]
    for k in sorted(row):
        if k == "name" or k in METRICS:
            continue
        v = row[k]
        if isinstance(v, (list, tuple, dict)):
            v = json.dumps(v, sort_keys=True)
        parts.append((k, str(v)))
    return tuple(parts)


def compare_rows(baseline: list[dict[str, Any]],
                 fresh: list[dict[str, Any]], *,
                 tol: float = 2.0) -> dict[str, Any]:
    """Diff two row lists; returns deltas + match accounting.

    Each delta is ``{"name", "metric", "baseline", "fresh", "ratio",
    "ok"}`` where ``ratio`` is fresh/baseline and ``ok`` applies the
    metric's direction with relative tolerance ``tol``.
    """
    base_by_key = {row_key(r): r for r in baseline}
    fresh_by_key = {row_key(r): r for r in fresh}
    matched = sorted(base_by_key.keys() & fresh_by_key.keys())
    deltas: list[dict[str, Any]] = []
    for key in matched:
        b, f = base_by_key[key], fresh_by_key[key]
        for metric, direction in METRICS.items():
            if direction == "ignore":
                continue
            bv, fv = b.get(metric), f.get(metric)
            if not (isinstance(bv, (int, float))
                    and isinstance(fv, (int, float)) and bv > 0 and fv > 0):
                continue
            ratio = fv / bv
            ok = (ratio <= 1.0 + tol if direction == "lower"
                  else ratio >= 1.0 / (1.0 + tol))
            deltas.append({"name": dict(key)["name"], "key": key,
                           "metric": metric, "baseline": bv, "fresh": fv,
                           "ratio": ratio, "ok": ok})
    return {
        "deltas": deltas,
        "matched": len(matched),
        "unmatched_baseline": len(base_by_key.keys() - fresh_by_key.keys()),
        "unmatched_fresh": len(fresh_by_key.keys() - base_by_key.keys()),
        "failures": [d for d in deltas if not d["ok"]],
    }


def compare_files(baseline_path: str, fresh_path: str, *,
                  tol: float = 2.0) -> dict[str, Any]:
    """Diff two benchmark JSON files (``{"rows": [...]}`` payloads)."""
    with open(baseline_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    out = compare_rows(base.get("rows", []), fresh.get("rows", []),
                       tol=tol)
    out["baseline_path"] = baseline_path
    out["fresh_path"] = fresh_path
    out["baseline_smoke"] = bool(base.get("smoke"))
    out["fresh_smoke"] = bool(fresh.get("smoke"))
    return out


def format_table(result: dict[str, Any]) -> str:
    """A readable delta table for one file pair."""
    lines = [f"{os.path.basename(result['baseline_path'])} "
             f"(baseline{' smoke' if result['baseline_smoke'] else ''}) "
             f"vs {result['fresh_path']}"
             f"{' (smoke)' if result['fresh_smoke'] else ''}: "
             f"{result['matched']} matched, "
             f"{result['unmatched_baseline']} baseline-only, "
             f"{result['unmatched_fresh']} fresh-only"]
    if result["deltas"]:
        w = max(len(d["name"]) for d in result["deltas"])
        lines.append(f"  {'row':<{w}}  {'metric':<15} "
                     f"{'baseline':>12} {'fresh':>12} {'ratio':>7}")
        for d in result["deltas"]:
            flag = "   " if d["ok"] else " <<< REGRESSION"
            lines.append(f"  {d['name']:<{w}}  {d['metric']:<15} "
                         f"{d['baseline']:>12.3g} {d['fresh']:>12.3g} "
                         f"{d['ratio']:>6.2f}x{flag}")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tol", type=float, default=2.0,
                    help="relative tolerance: fail when a row is more "
                         "than (1+tol)x worse than baseline")
    ap.add_argument("--strict", action="store_true",
                    help="fail on missing files instead of skipping")
    ap.add_argument("pairs", nargs="*",
                    help="baseline:fresh path pairs (default: the three "
                         "checked-in BENCH_*.json vs experiments/)")
    args = ap.parse_args(argv)
    if args.pairs:
        pairs = []
        for p in args.pairs:
            base, _, fresh = p.partition(":")
            if not fresh:
                ap.error(f"pair {p!r} must be baseline:fresh")
            pairs.append((base, fresh))
    else:
        pairs = [(os.path.join(_ROOT, b), os.path.join(_ROOT, f))
                 for b, f in DEFAULT_PAIRS]
    failed = False
    compared = 0
    for base, fresh in pairs:
        missing = [p for p in (base, fresh) if not os.path.exists(p)]
        if missing:
            print(f"skip {os.path.basename(base)}: missing "
                  + ", ".join(missing))
            if args.strict:
                failed = True
            continue
        result = compare_files(base, fresh, tol=args.tol)
        print(format_table(result))
        compared += result["matched"]
        if result["failures"]:
            failed = True
    if compared == 0:
        print("warning: no rows matched — identity keys (shape/app) "
              "differ between baseline and fresh runs")
    print("regression gate:", "FAIL" if failed else
          f"ok ({compared} rows within tolerance)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
