"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference.

On CPU the interpret-mode wall time is NOT the TPU performance; the
purpose is (a) a regression baseline and (b) exercising every kernel's
jit path end to end.  Derived column reports the analytic VMEM/flops.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import wall_us
from repro.kernels import ref as R
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_mlp import fused_mlp
from repro.kernels.ssd_scan import ssd_scan


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []

    B, Hq, Hkv, S, D = 1, 4, 2, 512, 128
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    rows.append({"name": "kernel/flash_attention(pallas-interp)",
                 "us": wall_us(lambda: flash_attention(q, k, v)),
                 "flops": 4 * B * Hq * S * S * D})
    rows.append({"name": "kernel/flash_attention(ref)",
                 "us": wall_us(lambda: R.flash_attention_ref(q, k, v))})

    T, d, f = 256, 512, 1024
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    wn = jnp.ones((d,), jnp.float32)
    wg = jnp.asarray(rng.normal(size=(d, f)) * .05, jnp.float32)
    wu = jnp.asarray(rng.normal(size=(d, f)) * .05, jnp.float32)
    wd = jnp.asarray(rng.normal(size=(f, d)) * .05, jnp.float32)
    rows.append({"name": "kernel/fused_mlp(pallas-interp)",
                 "us": wall_us(lambda: fused_mlp(x, wn, wg, wu, wd)),
                 "flops": 6 * T * d * f})
    rows.append({"name": "kernel/fused_mlp(ref)",
                 "us": wall_us(lambda: R.fused_mlp_ref(x, wn, wg, wu, wd))})

    b, s, h, p, g_, n = 1, 512, 8, 64, 1, 128
    xs = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(.01, .2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(.5, 2., size=(h,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, s, g_, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, s, g_, n)), jnp.float32)
    rows.append({"name": "kernel/ssd_scan(pallas-interp)",
                 "us": wall_us(lambda: ssd_scan(xs, dt, A, Bm, Cm,
                                                chunk=128))})
    rows.append({"name": "kernel/ssd_scan(ref)",
                 "us": wall_us(lambda: R.ssd_scan_ref(xs, dt, A, Bm, Cm,
                                                      chunk=128))})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
