"""Paper Fig. 8/9: one application source, multiple backends.

The paper synthesizes the SAME OpenCL source with Xilinx Vitis and the
Intel SDK, showing naive vs dataflow-optimized on both.  Our analogue:
one DataflowGraph lowered through all three backends (xla, xla_staged,
pallas), asserting bit-near-identical outputs and reporting per-backend
traffic + wall time — the portability contribution (C2+C4) without
touching the application code.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import wall_us
from repro.core import BACKENDS, compile_graph
from repro.core.apps import APPS

H = W = 1024


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    for app in ("gaussian_blur", "mean_filter", "jacobi", "filter_chain"):
        g0 = APPS[app][0](H, W)
        inputs = {c.name: rng.normal(size=(H, W)).astype(np.float32)
                  for c in g0.graph_inputs}
        ref = None
        for backend in BACKENDS:
            g = APPS[app][0](H, W)
            appc = compile_graph(g, backend=backend)
            out = appc(**inputs)
            vals = np.asarray(list(out.values())[0])
            if ref is None:
                ref = vals
            err = float(np.abs(vals - ref).max())
            assert err < 1e-3, (app, backend, err)
            cost = appc.cost()
            rows.append({
                "name": f"fig8/{app}/{backend}",
                "max_abs_diff_vs_first_backend": err,
                "hbm_bytes": int(cost["bytes_total"]),
                "cpu_wall_us": round(
                    wall_us(appc.fn,
                            *[inputs[n] for n in appc.input_names]), 1),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
