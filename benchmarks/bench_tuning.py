"""Profile-guided tuning sweep: tuned vs analytic vs default schedules.

Three schedule regimes, measured end-to-end on bench_parallel's app set
(gaussian_blur, filter_chain):

- **default** — the paper's explicit knob at its most conservative
  setting (``vector_factor=1``): what a user gets with no model and no
  measurements;
- **analytic** — PR 3's cost-model sweep (``compile_graph`` default):
  the model picks per-group tiles with zero measurements;
- **tuned** — ``tune="auto"``: the analytic sweep demoted to a prior,
  candidates *measured* on the live backend, winner persisted in the
  on-disk :class:`~repro.tune.store.TuningCache`.

Two invariants ride along (asserted in ``--smoke`` for CI):

1. the tuned schedule is never slower than the analytic pick — the
   analytic config is always one of the measured candidates, so the
   search winner bounds it by construction;
2. a second ``tune="auto"`` compile performs ZERO measurements — it is
   served entirely from the persistent cache (the bitstream-reuse
   property).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import compile_graph
from repro.core.apps import build_app
from repro.tune import TuningCache, tune_graph
import repro.tune.search as _search

_ROOT = os.path.join(os.path.dirname(__file__), "..")
_APPS = ("gaussian_blur", "filter_chain")      # bench_parallel's app set
_BACKEND = "pallas"


def _measured_us(app, h: int, w: int, reps: int) -> float:
    rng = np.random.default_rng(0)
    x = rng.normal(size=(h, w)).astype(np.float32)
    np.asarray(app(img=x)["out"])                  # warmup
    import time
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.asarray(app(img=x)["out"])
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def app_rows(name: str, h: int, w: int, reps: int,
             cache: TuningCache) -> list[dict]:
    rows = []

    default_app = compile_graph(build_app(name, h, w), _BACKEND,
                                vector_factor=1)
    rows.append({"name": f"tuning_{name}_default", "app": name,
                 "us": _measured_us(default_app, h, w, reps),
                 "vector_factors": [g.vector_factor
                                    for g in default_app.schedule.groups],
                 "source": "forced(vf=1)", "h": h, "w": w})

    analytic_app = compile_graph(build_app(name, h, w), _BACKEND)
    rows.append({"name": f"tuning_{name}_analytic", "app": name,
                 "us": _measured_us(analytic_app, h, w, reps),
                 "vector_factors": [g.vector_factor
                                    for g in analytic_app.schedule.groups],
                 "source": "model", "h": h, "w": w})

    result = tune_graph(build_app(name, h, w), _BACKEND, cache=cache,
                        reps=reps)
    assert result.source == "measured", result.source
    assert result.record.best_measured_s <= result.record.analytic_measured_s
    tuned_app = compile_graph(build_app(name, h, w), _BACKEND, tune="auto",
                              tune_cache=cache)
    rows.append({"name": f"tuning_{name}_tuned", "app": name,
                 "us": _measured_us(tuned_app, h, w, reps),
                 "vector_factors": [g.vector_factor
                                    for g in tuned_app.schedule.groups],
                 "source": "measured", "h": h, "w": w,
                 "config": result.config.to_json(),
                 "n_measurements": result.n_measurements,
                 "search_best_us": result.record.best_measured_s * 1e6,
                 "search_analytic_us":
                     result.record.analytic_measured_s * 1e6,
                 "trials": [{"label": t.label,
                             "modeled_us": t.modeled_s * 1e6,
                             "measured_us": t.measured_s * 1e6}
                            for t in result.trials]})

    # bitstream-reuse property: the second auto-tune measures NOTHING
    calls = {"n": 0}
    real = _search.default_measure

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    _search.default_measure = counting
    try:
        again = tune_graph(build_app(name, h, w), _BACKEND, cache=cache)
    finally:
        _search.default_measure = real
    assert again.source == "cache" and again.n_measurements == 0
    assert calls["n"] == 0, "cache hit must not re-measure"
    rows.append({"name": f"tuning_{name}_cached", "app": name, "us": 0.0,
                 "source": "cache", "n_measurements": 0,
                 "config": again.config.to_json(), "h": h, "w": w})

    # correctness: tuning picks tiles, never semantics
    rng = np.random.default_rng(1)
    x = rng.normal(size=(h, w)).astype(np.float32)
    a = np.asarray(analytic_app(img=x)["out"])
    b = np.asarray(tuned_app(img=x)["out"])
    assert np.array_equal(a, b), f"{name}: tuned changed bits"

    rows.append(calibrated_row(name, h, w, reps, result))
    return rows


def calibrated_row(name: str, h: int, w: int, reps: int,
                   uncal) -> dict:
    """Re-run the search under a calibrated prior and report the pruning.

    The prior comes from the checked-in golden drift fixture (the same
    rows ``tests/test_calibration.py`` pins), so this bench demonstrates
    the full loop: drift log -> fitted constants -> fewer measurements.
    The search must never measure *more* than the uncalibrated one; the
    hard strictly-fewer/same-winner property is asserted with an
    injected measure fn in the test suite, not here, because live
    timings can legitimately reorder near-tied candidates.
    """
    from repro.obs.drift import DriftRow
    from repro.tune.calibrate import calibrate

    fix = os.path.join(_ROOT, "tests", "fixtures",
                       "drift_bench_parallel.jsonl")
    with open(fix) as f:
        drift = [DriftRow.from_dict(json.loads(line)) for line in f]
    spec = calibrate(drift).spec
    with tempfile.TemporaryDirectory() as root:
        res = tune_graph(build_app(name, h, w), _BACKEND,
                         cache=TuningCache(root), reps=reps,
                         calibrate=spec)
    assert res.source == "measured", res.source
    assert res.n_measurements <= uncal.n_measurements, \
        (res.n_measurements, uncal.n_measurements)
    return {"name": f"tuning_{name}_calibrated", "app": name, "us": 0.0,
            "source": "measured+prior", "h": h, "w": w,
            "config": res.config.to_json(),
            "n_measurements": res.n_measurements,
            "n_pruned": res.n_pruned,
            "uncalibrated_n_measurements": uncal.n_measurements,
            "same_winner": res.config == uncal.config,
            "search_best_us": res.record.best_measured_s * 1e6}


def run(smoke: bool = False) -> list[dict]:
    h, w = (96, 256) if smoke else (256, 640)
    reps = 2 if smoke else 5
    apps = _APPS[:1] if smoke else _APPS
    rows = []
    with tempfile.TemporaryDirectory() as root:
        for name in apps:
            rows += app_rows(name, h, w, reps, TuningCache(root))
    if smoke:
        tuned = next(r for r in rows if r["name"].endswith("_tuned"))
        # tuned >= analytic, on the search's own measurements (the
        # analytic config is trial 0, so this holds by construction)
        assert tuned["search_best_us"] <= tuned["search_analytic_us"], tuned
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = run(smoke=smoke)
    for r in rows:
        extra = {k: v for k, v in r.items()
                 if k not in ("name", "us", "trials")}
        print(f"{r['name']}: {r['us']:.1f} us/call {extra}")
    payload = {"rows": rows, "smoke": smoke}
    os.makedirs(os.path.join(_ROOT, "experiments"), exist_ok=True)
    with open(os.path.join(_ROOT, "experiments", "bench_tuning.json"),
              "w") as f:
        json.dump(payload, f, indent=1)
    if not smoke:
        with open(os.path.join(_ROOT, "BENCH_tuning.json"), "w") as f:
            json.dump(payload, f, indent=1)
    if smoke:
        print("smoke ok: tuned <= analytic on the measured search, and "
              "the second tune was a zero-measurement cache hit")


if __name__ == "__main__":
    main()
