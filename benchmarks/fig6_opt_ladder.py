"""Paper Fig. 6: the optimization ladder — naive (AnyHLS-like, no
dataflow) -> dataflow -> +burst -> +vectorize.

The paper measures total kernel runtime on an Alveo U280 (6 launches,
1024x1024, 25.166 MB DMA) and finds up to 20x between AnyHLS (no
dataflow => no burst) and the full FLOWER pipeline.

Our measurable analogues, per rung, from the *compiled* artifact:
 - HBM traffic ("bytes accessed"): the staged baseline re-materializes
   every stage; the fused kernel touches each input/output once.
 - modeled v5e time: traffic / 819 GB/s + flops / 197 TFLOPs.
 - CPU wall-clock of the jitted program (relative sanity only).

Rungs: naive        = xla_staged (barrier between stages)
       dataflow     = fused pallas, vector_factor=1 (128-lane bursts)
       +burst       = fused pallas, vector_factor=4 (512-lane bursts)
       +vectorize   = fused pallas, automatic vector-factor sweep
                      (the cost model picks the widest profitable
                      datapath; see core/vectorize.select_tile).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import wall_us
from repro.core.apps import APPS
from repro.core.compiler import compile_graph
from repro.core.vectorize import V5E

H = W = 1024
LAUNCHES = 6
BENCH_APPS = ("gaussian_blur", "harris", "filter_chain", "unsharp_mask",
              "sobel_luma")


def modeled_ms(cost: dict) -> float:
    t = (cost["bytes_total"] / V5E.hbm_bw
         + cost["flops"] / V5E.peak_flops_bf16)
    return t * 1e3 * LAUNCHES


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    for app in BENCH_APPS:
        builder = APPS[app][0]
        inputs = {c.name: rng.normal(size=(H, W)).astype(np.float32)
                  for c in builder(H, W).graph_inputs}

        def rung(backend, vf, tile_note):
            g = builder(H, W)
            kw = dict(backend=backend, vector_factor=vf)
            app_c = compile_graph(g, **kw)
            cost = app_c.cost()
            us = wall_us(app_c.fn, *[inputs[n] for n in app_c.input_names])
            return cost, us

        ladder = [
            ("naive", "xla_staged", 1),
            ("dataflow", "pallas", 1),
            ("burst", "pallas", 4),
            ("vectorized", "pallas", None),   # automatic sweep
        ]
        base_bytes = None
        for label, backend, vf in ladder:
            cost, us = rung(backend, vf, label)
            if base_bytes is None:
                base_bytes = cost["bytes_total"]
            rows.append({
                "name": f"fig6/{app}/{label}",
                "hbm_bytes": int(cost["bytes_total"]),
                "bytes_vs_naive": round(cost["bytes_total"] / base_bytes, 3),
                "modeled_v5e_ms_6x": round(modeled_ms(cost), 3),
                "cpu_wall_us": round(us, 1),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
