"""Regenerate ``tests/fixtures/drift_bench_parallel.jsonl``.

The golden calibration fixture pins ROADMAP item 3's exit criterion as
a test: on these drift rows the *seed* spec's modeled-vs-measured
Spearman is **negative** while the *fitted* spec's is near 1.  The
ladder below is chosen to expose the seed model's failure mode, not to
flatter it: the seed prices a plane mostly by its padded element count
(DMA bytes / compute cycles at datasheet constants, with a token
1 us/step overhead), but interpreter-mode Pallas on a CPU host pays a
large *per-grid-step* dispatch cost — so pairs where the grid-step
count and the element count move in opposite directions (a tall
narrow plane at vf=1 vs. a short wide plane at max vf) invert the
seed's ranking.  The calibration fit recovers exactly that overhead
term from the recorded features, flipping the correlation.

Run from the repo root (takes ~a minute, interpreter mode):

    python benchmarks/make_calibration_fixture.py

and commit the regenerated fixture.  The companion test is
``tests/test_calibration.py::test_golden_fixture_*``.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import build_schedule, compile_graph, sweep_vector_factor
from repro.core.apps import build_app

_APP = "gaussian_blur"
_REPS = 5
_OUT = os.path.join(os.path.dirname(__file__), "..", "tests", "fixtures",
                    "drift_bench_parallel.jsonl")

#: ((H, W), vector_factor) — grid-step count vs. padded elements are
#: deliberately anti-correlated across the ladder (see module docstring):
#: the vf=1 rows run many small grid steps (overhead-dominated, cheap
#: under the seed model, slow in reality), the max-vf rows run a single
#: big step (element-dominated, expensive under the seed model, fast in
#: reality)
LADDER = [
    ((32, 2048), 1),       # grid 16, elements  65536
    ((64, 2048), 1),       # grid 16, elements 131072
    ((128, 1024), 1),      # grid  8, elements 131072
    ((32, 4096), 1),       # grid 32, elements 131072
    ((96, 2048), 1),       # grid 16, elements 196608
    ((256, 640), 5),       # grid  1, elements 163840
    ((256, 896), 7),       # grid  1, elements 229376
    ((256, 1024), 8),      # grid  1, elements 262144
]


def measure_rows() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    for (h, w), vf in LADDER:
        sched = build_schedule(build_app(_APP, h, w))
        rec = next(r for r in sweep_vector_factor(sched.groups[0])
                   if r["vector_factor"] == vf)
        assert rec["feasible"], ((h, w), vf)
        app = compile_graph(build_app(_APP, h, w), backend="pallas",
                            vector_factor=vf)
        x = rng.normal(size=(h, w)).astype(np.float32)

        def call() -> None:
            np.asarray(app(img=x)["out"])

        call()                                  # warmup (compiles)
        best = float("inf")
        for _ in range(_REPS):
            t0 = time.perf_counter()
            call()
            best = min(best, time.perf_counter() - t0)
        rows.append({"kind": "vf_sweep", "signature": sched.graph.signature(),
                     "shapes": [[h, w]], "backend": "pallas",
                     "modeled_s": rec["modeled_s"], "measured_s": best,
                     "attrs": {"vector_factor": vf,
                               "tile": list(rec["tile"]), "app": _APP,
                               "features": {"groups": [rec["features"]]}}})
        print(f"{h}x{w} vf{vf}: grid={rec['features']['grid']} "
              f"modeled={rec['modeled_s'] * 1e6:.1f}us "
              f"measured={best * 1e6:.1f}us")
    return rows


def main() -> None:
    from repro.obs.drift import DriftRow, drift_report
    from repro.tune.calibrate import calibrate

    raw = measure_rows()
    rows = [DriftRow.from_dict(d) for d in raw]
    seed = drift_report(rows)
    result = calibrate(rows)
    assert result.fitted, result.warning
    after = drift_report(rows, spec=result.spec)["with_spec"]
    print(f"\nseed:   spearman={seed['spearman']:+.3f} "
          f"bias={seed['bias']:.2f}")
    print(f"fitted: spearman={after['spearman']:+.3f} "
          f"bias={after['bias']:.2f}  ({result.describe()})")
    if seed["spearman"] > 0:
        print("WARNING: seed spearman is positive; the fixture will not "
              "pin the inversion — re-tune the ladder for this machine")
    if after["spearman"] <= 0.8:
        print("WARNING: fitted spearman <= 0.8 — fit did not converge "
              "on this ladder")
    os.makedirs(os.path.dirname(_OUT), exist_ok=True)
    with open(_OUT, "w") as f:
        for d in raw:
            f.write(json.dumps(d) + "\n")
    print(f"wrote {len(raw)} rows -> {_OUT}")


if __name__ == "__main__":
    main()
