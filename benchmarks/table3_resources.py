"""Paper Table III: post-synthesis resource usage per application.

FPGA resources (CLB/LUT/FF/DSP/BRAM/SRL) have no TPU equivalent; the
analogous budget is the fused kernel's VMEM working set (the paper's
BRAM), the streamed burst size (DMA efficiency), the number of live
FIFO channels (registers/buffers), and compiled code size.
"""
from __future__ import annotations

import numpy as np

from repro.core import build_schedule, compile_graph
from repro.core.apps import APPS
from repro.core.vectorize import vmem_report

H = W = 1024


def run() -> list[dict]:
    rows = []
    for app in ("gaussian_blur", "laplace", "mean_filter", "sobel",
                "harris", "bilateral_filter"):
        g = APPS[app][0](H, W)
        sched = build_schedule(g)        # auto vector-factor sweep
        grp = sched.groups[0]
        rep = vmem_report(grp)
        appc = compile_graph(g, backend="pallas")
        mem = appc.memory()
        rows.append({
            "name": f"table3/{app}",
            "tile": rep["tile"],
            "vmem_bytes": rep["vmem_bytes"],          # ~ BRAM
            "burst_bytes": rep["burst_bytes"],        # ~ AXI burst
            "fifo_channels": rep["n_channels"],       # ~ FF/SRL
            "stages": len(grp.stages),                # ~ pipeline depth
            "temp_bytes_compiled": mem.get("temp_size_in_bytes", 0),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
