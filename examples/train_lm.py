"""End-to-end training driver: data -> sharded step -> checkpoints.

The generated "host code" at LM scale: pick an architecture config,
the launcher derives shardings, the step function, checkpointing and
fault handling; you only choose the preset.

Presets:
  tiny   ~2M params, a few hundred steps on CPU        (default; CI)
  100m   ~100M params — the assignment's end-to-end target (slow on
         CPU, appropriate on a real accelerator)
  <arch> any assigned architecture's SMOKE config by name

Run:  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses

from repro.configs import ARCHS, get_smoke
from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig

PRESETS = {
    "tiny": ModelConfig(
        name="tiny-llama", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=2048,
        dtype="float32", remat="none"),
    "100m": ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32768,
        dtype="float32", remat="none"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny",
                    help=f"tiny | 100m | one of {ARCHS}")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = (PRESETS[args.preset] if args.preset in PRESETS
           else get_smoke(args.preset))
    print(f"model {cfg.name}: {cfg.n_params()/1e6:.1f}M params")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.batch)
    opt = AdamWConfig(lr_peak=args.lr, warmup_steps=20,
                      decay_steps=args.steps)
    tc = TrainerConfig(total_steps=args.steps, ckpt_every=100,
                       ckpt_dir=args.ckpt_dir, log_every=20)
    tr = Trainer(cfg, opt, tc, data)
    hist = tr.run()
    print(f"\nloss: {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} over "
          f"{len(hist)} steps  "
          f"({sum(h['step_time_s'] for h in hist):.1f}s total)")
    assert hist[-1]["loss"] < hist[0]["loss"], "training did not learn"
    print("OK")


if __name__ == "__main__":
    main()
