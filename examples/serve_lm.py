"""Batched serving driver: prefill + decode with a KV cache.

Demonstrates the serving half of the generated host code: batch of
prompts -> prefill (cache fill) -> token-by-token greedy decode, with
per-phase timing and cache statistics.  Works for every assigned arch
(attention KV caches, MLA latent caches, SSM states, hybrid mixes).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch mamba2_2p7b
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_smoke
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b", help=f"one of {ARCHS}")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = M.init(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.gen_len + 8
    B = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (B, args.prompt_len), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embeds"] = jnp.zeros((B, cfg.n_frontend_tokens,
                                      cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        kw["extra_embeds"] = jnp.zeros((B, cfg.n_frontend_tokens,
                                        cfg.d_model), jnp.float32)

    prefill = jax.jit(lambda p, t, c: M.prefill(p, cfg, t, c, **kw))
    decode = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c))

    cache = M.init_cache(cfg, B, max_len, dtype=jnp.float32)
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"{cfg.name}: cache {cache_bytes/1e6:.2f} MB for B={B} "
          f"max_len={max_len}")

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompt, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.gen_len - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, 1)
    print(f"prefill: {t_prefill*1e3:8.1f} ms "
          f"({B*args.prompt_len/t_prefill:8.0f} tok/s)")
    print(f"decode:  {t_decode*1e3:8.1f} ms "
          f"({B*(args.gen_len-1)/t_decode:8.0f} tok/s)")
    print(f"generated (first row): {gen[0][:16]}...")
    assert np.isfinite(gen).all()
    print("OK")


if __name__ == "__main__":
    main()
