"""Quickstart: the paper's running example (Section IV).

A single-source program: apply fun1 and fun2 to one image and combine
with fun3.  Note there is NO explicit split below — ``in_img`` is
simply read twice, which the seed compiler rejected.  The pass-based
pipeline (`repro.core.compiler.compile_graph`) canonicalizes it
automatically (AutoSplitInsertion), fuses all tasks into ONE streaming
kernel by convex DAG fusion (depth-2 FIFOs == double-buffered VMEM
tiles), assigns memory bundles, and generates the host launcher —
exactly the paper's workflow, on TPU abstractions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import DataflowGraph, compile_graph


def main():
    H, W = 512, 1024
    g = DataflowGraph("quickstart")

    in_img = g.input("in_img", (H, W))                    # read_image
    t1 = g.point(in_img, lambda x: x * 2.0 + 1.0, name="fun1")
    t2 = g.stencil(in_img, (5, 5),                        # 2nd read of in_img!
                   lambda p: sum(p[i] for i in range(25)) / 25.0,
                   name="fun2")
    out = g.point2(t1, t2, lambda a, b: a - b, name="fun3")
    g.output(out, "out_img")                              # image_write

    # --- the compiler pipeline ---------------------------------------
    # validate -> canonicalize (auto-split, DCE, point fusion)
    #          -> convex DAG fusion -> lower -> host codegen
    app = compile_graph(g, backend="pallas")              # fused kernel
    print(app.schedule.describe(), "\n")                  # incl. pass log
    print(app.host_program(), "\n")                      # generated host

    x = np.random.default_rng(0).normal(size=(H, W)).astype(np.float32)
    out = app(in_img=x)["out_img"]
    ref = app.schedule.graph.reference_eval({"in_img": x})["out_img"]
    err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
    print(f"fused-vs-reference max |err| = {err:.2e}")
    print(f"HBM traffic (compiled): {app.cost()['bytes_total']/1e6:.1f} MB")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
