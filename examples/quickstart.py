"""Quickstart: the paper's running example (Section IV), single-source.

The program below is plain array code: operators for point math,
``fe.conv`` for the local operator.  There is NO DataflowGraph, no
channel, no split anywhere — tracing extracts the graph (``in_img``
is simply read twice; AutoSplitInsertion makes the fan-out explicit),
the pass pipeline canonicalizes it, convex DAG fusion collapses all
tasks into ONE streaming kernel (depth-2 FIFOs == double-buffered
VMEM tiles), and host codegen produces the launcher — the paper's
whole workflow from one decorated function.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.frontend as fe


@fe.dataflow_fn(backend="pallas")
def quickstart(in_img):
    fun1 = 2.0 * in_img + 1.0                       # point task
    fun2 = fe.conv(in_img, np.ones((5, 5), np.float32) / 25.0)  # local task
    return {"out_img": fun1 - fun2}                 # point task + write


def main():
    H, W = 512, 1024
    x = np.random.default_rng(0).normal(size=(H, W)).astype(np.float32)

    # --- the compiler pipeline ---------------------------------------
    # trace -> canonicalize (auto-split, DCE, point fusion)
    #       -> convex DAG fusion -> lower -> host codegen
    app = quickstart.compile(x)                     # fused pallas kernel
    print("frontend log:", *app.graph.frontend_log, sep="\n  ")
    print()
    print(app.schedule.describe(), "\n")            # incl. pass log
    print(app.host_program(), "\n")                # generated host

    out = quickstart(x)["out_img"]                  # trace+compile memoized
    ref = app.schedule.graph.reference_eval({"in_img": x})["out_img"]
    err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
    print(f"fused-vs-reference max |err| = {err:.2e}")
    print(f"HBM traffic (compiled): {app.cost()['bytes_total']/1e6:.1f} MB")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
