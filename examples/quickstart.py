"""Quickstart: the paper's running example (Section IV).

A single-source program: split an image into two streams, apply fun1
and fun2, combine with fun3.  FLOWER extracts the dataflow graph,
validates it, fuses all tasks into ONE streaming kernel (depth-2 FIFOs
== double-buffered VMEM tiles), assigns memory bundles, and generates
the host launcher — exactly the paper's workflow, on TPU abstractions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import DataflowGraph, build_schedule, compile_graph


def main():
    H, W = 512, 1024
    g = DataflowGraph("quickstart")

    in_img = g.input("in_img", (H, W))                    # read_image
    s1, s2 = g.split(in_img, name="split_image")          # split_image
    t1 = g.point(s1, lambda x: x * 2.0 + 1.0, name="fun1")
    t2 = g.stencil(s2, (5, 5), lambda p: sum(p[i] for i in range(25)) / 25.0,
                   name="fun2")
    out = g.point2(t1, t2, lambda a, b: a - b, name="fun3")
    g.output(out, "out_img")                              # image_write

    # --- the compiler pipeline ---------------------------------------
    sched = build_schedule(g)
    print(sched.describe(), "\n")

    app = compile_graph(g, backend="pallas")              # fused kernel
    print(app.host_program(), "\n")                      # generated host

    x = np.random.default_rng(0).normal(size=(H, W)).astype(np.float32)
    out = app(in_img=x)["out_img"]
    ref = g.reference_eval({"in_img": x})["out_img"]
    err = float(np.abs(np.asarray(out) - np.asarray(ref)).max())
    print(f"fused-vs-reference max |err| = {err:.2e}")
    print(f"HBM traffic (compiled): {app.cost()['bytes_total']/1e6:.1f} MB")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
