"""Lucas-Kanade optical flow — the paper's Fig. 4 16-stage pipeline.

The LK graph (derivatives, products, windowed sums, 2x2 solve) is now
a *traced single-source program*: `repro.core.apps.optical_flow_lk`
is plain array code (`it = f2 - f1`, `ixx = ix * ix`, `fe.conv`, …)
that the frontend extracts into the dataflow graph — every split
stage below was inserted automatically.  The pass pipeline
canonicalizes it, convex DAG fusion collapses all 16 stages into one
streaming kernel, and the example estimates motion on a synthetic
translating pattern.  Demonstrates memory-bundle assignment across
the parallel DAG paths (the paper's mem1..4).

Run:  PYTHONPATH=src python examples/optical_flow.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import build_schedule, compile_graph
from repro.core.apps import optical_flow_lk


def main():
    H, W = 256, 512
    g = optical_flow_lk(H, W)          # traced from plain array code
    sched = build_schedule(g)
    n_split = sum(1 for s in sched.graph.stages if s.kind == "split")
    print(f"LK graph: {len(sched.graph.stages)} tasks "
          f"({len(sched.graph.stages) - n_split} compute + {n_split} "
          f"auto-inserted splits), fused into {len(sched.groups)} "
          f"kernel(s) by convex DAG fusion")
    print("memory bundles:",
          {c.name: f"mem{b}" for c, b in sched.bundles.items()})

    # synthetic scene: smooth random texture translated by (dy, dx)
    rng = np.random.default_rng(0)
    base = rng.normal(size=(H + 8, W + 8)).astype(np.float32)
    k = np.ones((9, 9), np.float32) / 81.0
    from numpy.lib.stride_tricks import sliding_window_view
    smooth = sliding_window_view(base, (9, 9)).reshape(H, W, 81) @ k.ravel()
    dy, dx = 1, 1   # LK linearizes: keep sub-2px motion
    f1 = smooth[: H - 4, : W - 4]
    f2 = smooth[dy: H - 4 + dy, dx: W - 4 + dx]

    app = compile_graph(g, backend="pallas")
    # note: the app was built for (H, W); rebuild at the frame size
    g2 = optical_flow_lk(*f1.shape, eps=1e-8)
    app = compile_graph(g2, backend="pallas")
    out = app(f1=f1, f2=f2)
    vx = np.asarray(out["vx"])[16:-16, 16:-16]
    vy = np.asarray(out["vy"])[16:-16, 16:-16]
    # convention: f2(y,x) = f1(y+dy, x+dx) shifts content by (-dy,-dx),
    # so LK should report flow ~= (-dx, -dy).
    print(f"estimated flow: vx median={np.median(vx):+.2f} (true {-dx}), "
          f"vy median={np.median(vy):+.2f} (true {-dy})")
    ok = (abs(np.median(vx) + dx) < 0.75
          and abs(np.median(vy) + dy) < 0.75)
    print("OK" if ok else "flow estimate out of tolerance")
    assert ok


if __name__ == "__main__":
    main()
