"""Serve a compiled dataflow app with the StreamEngine.

The paper's generated host code runs ONE app launch through an XRT
command queue; this example runs the same compiled diamond app as a
long-lived *service*: requests flow through a bounded FIFO (the
queue-depth backpressure of `core/simulate.py`, live), same-topology
requests hit the compile cache instead of re-tracing, consecutive
requests are micro-batched into one vmapped kernel launch, and two
launches stay in flight at once (double buffering).  At the end the
engine prints its telemetry next to the Fig. 1 analytic prediction.

Run:  PYTHONPATH=src python examples/serve_dataflow.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import DataflowGraph
from repro.core.apps import JACOBI3, LAPLACE3, _conv
from repro.runtime import StreamEngine


def diamond(h: int, w: int) -> DataflowGraph:
    """split -> two stencil branches -> merge (fuses to ONE kernel)."""
    g = DataflowGraph("diamond")
    x = g.input("x", (h, w))
    s1 = g.stencil(x, (3, 3), _conv(LAPLACE3), name="lap")
    s2 = g.stencil(x, (3, 3), _conv(JACOBI3), name="jac")
    g.output(g.point2(s1, s2, lambda u, v: u - v, name="merge"), "y")
    return g


def main():
    H, W, N = 48, 256, 32
    rng = np.random.default_rng(0)
    frames = [rng.normal(size=(H, W)).astype(np.float32) for _ in range(N)]
    g = diamond(H, W)

    with StreamEngine(backend="pallas", max_batch=8, max_queue=64) as eng:
        # submit the whole stream; each handle is a future
        handles = [eng.submit(g, {"x": f}) for f in frames]
        results = [h.result(timeout=300) for h in handles]
        report = eng.report()

    # every request is bit-exact against the reference oracle
    app = eng.cache.get(g, backend="pallas")
    ref_graph = app.schedule.graph
    for f, r in zip(frames, results):
        ref = np.asarray(ref_graph.reference_eval({"x": f})["y"])
        np.testing.assert_array_equal(r["y"], ref)
    print(f"{N} requests served, all bit-exact vs reference_eval\n")

    m = report["measured"]
    print("measured:")
    print(f"  completed          {m['completed']}")
    print(f"  throughput         {m['throughput_rps']:.1f} req/s")
    print(f"  latency p50 / p99  {m['latency_p50_ms']:.1f} / "
          f"{m['latency_p99_ms']:.1f} ms")
    print(f"  mean queue depth   {m['queue_depth_mean']:.1f}")
    print(f"  mean batch size    {m['batch_size_mean']:.1f}")
    c = report["cache"]
    print(f"cache: {c['misses']} compile for {c['requests']} requests")
    mod = report["modeled"]["diamond"]
    print("modeled (Fig. 1, cycles):")
    print(f"  sequential {mod['sequential']:.0f}  dataflow "
          f"{mod['dataflow']:.0f}  speedup {mod['speedup']:.2f}x")
    assert c["misses"] == 1 and c["requests"] == N
    print("OK")


if __name__ == "__main__":
    main()
