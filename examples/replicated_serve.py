"""Serve one dataflow app with BOTH hardware-parallelism axes.

FLOWER's transformation taxonomy (after de Fine Licht et al.) widens
a processing element (*vectorization*) and duplicates it
(*replication*).  This example runs the same compiled stencil chain
three ways and prints the telemetry side by side:

1. plain compiled app — the vector-factor sweep picks the tile,
2. spatially replicated app — the plane row-partitioned over every
   visible device with ring halo exchange (`replicate_app`),
3. replicated serving farm — `StreamEngine(replicas=k)` shards each
   padded micro-batch across the devices.

On a single-device host everything still runs (the 1-replica
shard_map fallback); force extra CPU devices to see real sharding:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/replicated_serve.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import compile_graph
from repro.core.apps import build_app
from repro.parallel.replicate import replicate_app
from repro.runtime import StreamEngine


def main():
    H, W, N = 96, 256, 32
    n_dev = len(jax.devices())
    k = max(d for d in range(1, n_dev + 1) if H % d == 0)
    rng = np.random.default_rng(0)
    frames = [rng.normal(size=(H, W)).astype(np.float32) for _ in range(N)]

    g = build_app("filter_chain", H, W)
    app = compile_graph(build_app("filter_chain", H, W), backend="pallas")
    print("=== compiled app (auto vector-factor sweep) ===")
    print(app.schedule.describe(), "\n")

    print(f"=== spatial replication over {k} device(s) ===")
    rapp = replicate_app(app, k)
    print(rapp.describe().splitlines()[0])
    print(rapp.describe().splitlines()[1])
    ref = np.asarray(app(img=frames[0])["out"])
    out = np.asarray(rapp(img=frames[0])["out"])
    assert np.array_equal(out, ref)
    print("replicated output bit-exact vs single-device: True\n")

    print(f"=== serving farm: StreamEngine(replicas={k}) ===")
    with StreamEngine(backend="pallas", max_batch=8 * k, replicas=k,
                      max_queue=N) as eng:
        handles = [eng.submit(g, {"img": f}) for f in frames]
        results = [h.result(timeout=300) for h in handles]
        report = eng.report()
    for f, r in zip(frames, results):
        np.testing.assert_array_equal(
            r["out"], np.asarray(app(img=f)["out"]))
    m = report["measured"]
    print(f"  completed              {m['completed']}")
    print(f"  throughput             {m['throughput_rps']:.1f} req/s "
          f"({m['throughput_per_replica_rps']:.1f} per replica)")
    print(f"  latency p50 / p99      {m['latency_p50_ms']:.1f} / "
          f"{m['latency_p99_ms']:.1f} ms")
    modeled = next(iter(report["modeled"].values()))
    if "replica_scaling_modeled" in modeled:
        print(f"  modeled farm scaling   "
              f"{modeled['replica_scaling_modeled']:.2f}x "
              f"(linear would be {k}x)")
    print("\nall outputs bit-exact across every parallel mode")


if __name__ == "__main__":
    main()
